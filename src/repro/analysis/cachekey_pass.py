"""Pass 2 — cache-key completeness: no simulate-affecting knob may
bypass the content-addressed cache.

The explore plane memoises ``simulate()`` results under
``ExploreJob.content_key`` (sha256 over ``explore.job.canonical``).
Historically this contract broke silently three times — a new
simulate-affecting field landed without a ``CACHE_SCHEMA`` bump and
stale caches served wrong numbers.  This pass AST-diffs the three
anchors so the contract is machine-checked:

* ``core/costmodel.py::simulate`` — the semantic parameter surface,
* ``explore/job.py::ExploreJob`` — the cached key's field set (hashed
  generically: ``canonical`` must enumerate dataclass fields via
  ``_sorted_field_names``/``dataclasses.fields``, never a hand list),
* ``explore/runner.py::evaluate_job`` — the forwarding glue,
* the numbered ``# N:`` history block above ``CACHE_SCHEMA``.

Declared exceptions (each must stay justified here):

* ``tile_cache`` is a *memo*, not a semantic input — simulate results
  are bit-identical with or without it, so it must NOT enter the key.
* ``kind`` is key metadata (dense-twin vs simulate) consumed by
  ``evaluate_job``'s dispatch, not forwarded as a simulate kwarg.

Codes
-----
* ``CIM200`` (error) — an anchor (file/function/class) moved and the
  pass can no longer see it; fix the pass alongside the refactor.
* ``CIM201`` (error) — ``simulate()`` keyword absent from
  ``ExploreJob``: results would vary on a knob the cache key ignores.
* ``CIM202`` (error) — ``ExploreJob`` field never read by
  ``evaluate_job``: the key varies on a knob the evaluation ignores
  (dead weight at best, a stale-key refactor remnant at worst).
* ``CIM203`` (error) — ``canonical()`` no longer enumerates dataclass
  fields generically, so new fields would silently skip the digest.
* ``CIM204`` (error) — ``CACHE_SCHEMA`` has no matching ``# N:`` history
  entry for its current value.
* ``CIM205`` (error) — observability leaking into the cache key: an
  ``ExploreJob`` field or ``simulate()`` parameter named after the obs
  plane (``*obs*``), or ``explore/job.py`` importing ``repro.obs`` at
  all.  ``repro.obs`` is observational-only (it may read wall clocks,
  see the determinism pass waiver) — if any obs-derived value entered
  ``canonical()``, cache keys would vary run to run and the memoisation
  contract would dissolve.
* ``CIM206`` (error) — execution policy leaking into the cache key: an
  ``ExploreJob`` field or ``simulate()`` parameter with a fault/retry/
  timeout/backoff name, or ``explore/job.py`` importing
  ``repro.explore.faults``.  Retry budgets, timeouts and fault plans
  change how a sweep *executes*, never what a job *computes* — they are
  runner-level knobs by contract (``SweepRunner(timeout_s=…,
  max_retries=…)``), and if one entered ``canonical()``, identical
  simulations run under different robustness settings would stop
  sharing cache entries (and a fault-injected chaos run would poison
  the fault-free cache namespace).
* ``CIM207`` (error) — batching/search execution knobs leaking into the
  cache key: an ``ExploreJob`` field or ``simulate()`` parameter with a
  batch/search/budget name, or ``explore/job.py`` importing
  ``repro.explore.batch`` / ``repro.explore.search``.  Batched
  evaluation is bit-identical to per-point evaluation by contract
  (``tests/test_batch.py``), and a guided search merely chooses *which*
  points evaluate — neither changes what a job computes.  If either
  entered ``canonical()``, a point found by ``--search halving`` under
  ``--batch 256`` would stop sharing its store entry with the same
  point in a plain exhaustive sweep, and resumability across execution
  configurations would dissolve.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .diagnostics import Diagnostic, Severity
from .framework import AnalysisPass, PassContext, register

__all__ = ["CacheKeyPass", "NON_SEMANTIC_SIMULATE_PARAMS",
           "NON_FORWARDED_JOB_FIELDS"]

# simulate() parameters that deliberately stay out of the cache key
# (pure memoisation, bit-identical results either way).
NON_SEMANTIC_SIMULATE_PARAMS = frozenset({"tile_cache"})

# ExploreJob fields that deliberately aren't forwarded to simulate()
# (consumed by evaluate_job's own dispatch instead).
NON_FORWARDED_JOB_FIELDS = frozenset({"kind"})

# name tokens that mark an execution-policy knob (CIM206): these belong
# on SweepRunner, never on the cache-key surface
_FAULT_TOKENS = frozenset({"fault", "faults", "retry", "retries",
                           "timeout", "timeouts", "backoff"})

# name tokens that mark a batching/search execution knob (CIM207):
# batched evaluation and guided search change how a sweep executes,
# never what a job computes
_BATCH_TOKENS = frozenset({"batch", "batched", "batches", "search",
                           "budget"})

_HISTORY_RE = re.compile(r"^\s*#\s*(\d+)\s*:")


def _find_def(tree: ast.Module, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _find_class(tree: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _dataclass_fields(cls: ast.ClassDef) -> Dict[str, int]:
    """Annotated instance fields of a dataclass body: name -> lineno
    (ClassVar annotations and underscored names excluded)."""
    fields: Dict[str, int] = {}
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign):
            continue
        if not isinstance(stmt.target, ast.Name):
            continue
        ann = ast.unparse(stmt.annotation)
        if "ClassVar" in ann or stmt.target.id.startswith("_"):
            continue
        fields[stmt.target.id] = stmt.lineno
    return fields


def _signature_params(fn: ast.FunctionDef) -> Dict[str, int]:
    """All named parameters (positional + kw-only): name -> lineno."""
    params: Dict[str, int] = {}
    for a in (*fn.args.posonlyargs, *fn.args.args, *fn.args.kwonlyargs):
        params[a.arg] = a.lineno
    return params


def _attr_reads(fn: ast.FunctionDef, base: str) -> Set[str]:
    """Attribute names read off ``<base>.`` anywhere in the body."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == base):
            out.add(node.attr)
    return out


def _schema_assignment(tree: ast.Module) -> Optional[Tuple[int, int]]:
    """(value, lineno) of the module-level ``CACHE_SCHEMA = <int>``."""
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "CACHE_SCHEMA"
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)):
            return node.value.value, node.lineno
    return None


def _history_entries(lines: List[str], assign_lineno: int) -> Set[int]:
    """``# N:`` entries in the contiguous comment block directly above
    the CACHE_SCHEMA assignment."""
    entries: Set[int] = set()
    i = assign_lineno - 2                     # line above, 0-based
    while i >= 0 and lines[i].lstrip().startswith("#"):
        m = _HISTORY_RE.match(lines[i])
        if m:
            entries.add(int(m.group(1)))
        i -= 1
    return entries


@register
class CacheKeyPass(AnalysisPass):
    name = "cache-key"
    codes = ("CIM200", "CIM201", "CIM202", "CIM203", "CIM204", "CIM205",
             "CIM206", "CIM207")
    description = ("every simulate() knob must flow through ExploreJob, "
                   "canonical() must hash fields generically, "
                   "CACHE_SCHEMA history must cover the current value, "
                   "and nothing obs-, fault-policy-, or batch/search-"
                   "derived may enter the key")

    def _missing(self, what: str, rel: str) -> Diagnostic:
        return self.diag(
            "CIM200", Severity.ERROR,
            f"cache-key anchor not found: {what}",
            file=rel,
            hint="the cache-key pass tracks this symbol by name; update "
                 "repro/analysis/cachekey_pass.py with the refactor")

    def run(self, ctx: PassContext) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        pkg = ctx.package

        cost_path = ctx.module_path(f"{pkg}.core.costmodel")
        job_path = ctx.module_path(f"{pkg}.explore.job")
        runner_path = ctx.module_path(f"{pkg}.explore.runner")
        for what, p in (("core/costmodel.py", cost_path),
                        ("explore/job.py", job_path),
                        ("explore/runner.py", runner_path)):
            if p is None:
                diags.append(self._missing(what, what))
        if any(p is None for p in (cost_path, job_path, runner_path)):
            return diags

        cost_rel, job_rel = ctx.rel(cost_path), ctx.rel(job_path)
        runner_rel = ctx.rel(runner_path)

        simulate = _find_def(ctx.tree(cost_path), "simulate")
        job_cls = _find_class(ctx.tree(job_path), "ExploreJob")
        canonical = _find_def(ctx.tree(job_path), "canonical")
        evaluate = _find_def(ctx.tree(runner_path), "evaluate_job")
        if simulate is None:
            diags.append(self._missing("simulate()", cost_rel))
        if job_cls is None:
            diags.append(self._missing("class ExploreJob", job_rel))
        if canonical is None:
            diags.append(self._missing("canonical()", job_rel))
        if evaluate is None:
            diags.append(self._missing("evaluate_job()", runner_rel))
        if None in (simulate, job_cls, canonical, evaluate):
            return diags

        params = _signature_params(simulate)
        fields = _dataclass_fields(job_cls)

        # CIM201 — simulate knob missing from the cache key
        for name, lineno in sorted(params.items()):
            if name in fields or name in NON_SEMANTIC_SIMULATE_PARAMS:
                continue
            diags.append(self.diag(
                "CIM201", Severity.ERROR,
                f"simulate() parameter {name!r} is not an ExploreJob "
                f"field — cached results would ignore it",
                file=cost_rel, line=lineno,
                hint=f"add {name!r} to ExploreJob (it enters canonical() "
                     f"automatically), bump CACHE_SCHEMA with a history "
                     f"entry, and forward it in evaluate_job; if it is "
                     f"pure memoisation, whitelist it in "
                     f"NON_SEMANTIC_SIMULATE_PARAMS with a justification"))

        # CIM202 — key field the evaluation never reads
        reads = _attr_reads(evaluate, "job")
        for name, lineno in sorted(fields.items()):
            if name in reads or name in NON_FORWARDED_JOB_FIELDS:
                continue
            diags.append(self.diag(
                "CIM202", Severity.ERROR,
                f"ExploreJob field {name!r} is never read by "
                f"evaluate_job — the cache key varies on a knob the "
                f"evaluation ignores",
                file=job_rel, line=lineno,
                hint="forward it to simulate() in evaluate_job, or drop "
                     "the field (bumping CACHE_SCHEMA either way)"))

        # CIM203 — canonical() must enumerate dataclass fields generically
        calls = {node.func.id if isinstance(node.func, ast.Name)
                 else getattr(node.func, "attr", "")
                 for node in ast.walk(canonical)
                 if isinstance(node, ast.Call)}
        if not calls & {"_sorted_field_names", "fields"}:
            diags.append(self.diag(
                "CIM203", Severity.ERROR,
                "canonical() no longer enumerates dataclass fields "
                "generically (_sorted_field_names / dataclasses.fields) "
                "— new fields would silently skip the content key",
                file=job_rel, line=canonical.lineno,
                hint="hash dataclasses via their full sorted field set; "
                     "hand-maintained field lists rot"))

        # CIM205 — nothing obs-derived may enter the cache key.  Two
        # shapes of the leak: (a) a field/parameter named after the obs
        # plane, (b) explore/job.py importing repro.obs (even lazily —
        # the key module has no observational business at all).
        for name, lineno, rel in (
                [(n, ln, job_rel) for n, ln in sorted(fields.items())]
                + [(n, ln, cost_rel) for n, ln in sorted(params.items())]):
            if "obs" in name.lower().split("_") or name.lower() == "obs":
                diags.append(self.diag(
                    "CIM205", Severity.ERROR,
                    f"obs-derived name {name!r} in the cache-key surface "
                    f"— instrumentation must stay observational",
                    file=rel, line=lineno,
                    hint="repro.obs reads wall clocks under a sanctioned "
                         "waiver; letting its state into ExploreJob/"
                         "simulate() would make cache keys nondeterministic"))
        for node in ast.walk(ctx.tree(job_path)):
            target = ""
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[:2] == [pkg, "obs"]:
                        target = alias.name
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level > 0:
                    names = {a.name for a in node.names}
                    if mod.split(".")[0] == "obs" or (
                            not mod and "obs" in names):
                        target = f"{pkg}.obs"
                elif mod.split(".")[:2] == [pkg, "obs"]:
                    target = mod
            if target:
                diags.append(self.diag(
                    "CIM205", Severity.ERROR,
                    f"explore/job.py imports {target} — the cache-key "
                    f"module must not touch the observability plane",
                    file=job_rel, line=node.lineno,
                    hint="record telemetry in the runner/sweeps layer; "
                         "job.py defines the memoisation contract and "
                         "stays obs-free by construction"))

        # CIM206 — execution policy may not enter the cache key.  Same
        # two leak shapes as CIM205: (a) a fault/retry/timeout/backoff-
        # named field or parameter, (b) explore/job.py importing the
        # fault-injection harness (repro.explore.faults).
        for name, lineno, rel in (
                [(n, ln, job_rel) for n, ln in sorted(fields.items())]
                + [(n, ln, cost_rel) for n, ln in sorted(params.items())]):
            tokens = set(name.lower().split("_")) | {name.lower()}
            if tokens & _FAULT_TOKENS:
                diags.append(self.diag(
                    "CIM206", Severity.ERROR,
                    f"execution-policy name {name!r} in the cache-key "
                    f"surface — fault/retry/timeout knobs are "
                    f"runner-level by contract",
                    file=rel, line=lineno,
                    hint="put the knob on SweepRunner (timeout_s, "
                         "max_retries, backoff_s, failure_mode) or in a "
                         "FaultPlan; a job's key must not vary with how "
                         "robustly the sweep executes it"))
        for node in ast.walk(ctx.tree(job_path)):
            target = ""
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[:3] == [pkg, "explore",
                                                     "faults"]:
                        target = alias.name
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level > 0:
                    names = {a.name for a in node.names}
                    if mod.split(".")[0] == "faults" or (
                            not mod and "faults" in names):
                        target = f"{pkg}.explore.faults"
                elif mod.split(".")[:3] == [pkg, "explore", "faults"]:
                    target = mod
            if target:
                diags.append(self.diag(
                    "CIM206", Severity.ERROR,
                    f"explore/job.py imports {target} — the cache-key "
                    f"module must not touch the fault-injection plane",
                    file=job_rel, line=node.lineno,
                    hint="inject faults in the runner/cache layer "
                         "(evaluate_job, ResultStore.put); job.py "
                         "defines the memoisation contract and stays "
                         "fault-free by construction"))

        # CIM207 — batching/search knobs may not enter the cache key.
        # Same two leak shapes again: (a) a batch/search/budget-named
        # field or parameter, (b) explore/job.py importing the batched
        # evaluator or the search layer.
        for name, lineno, rel in (
                [(n, ln, job_rel) for n, ln in sorted(fields.items())]
                + [(n, ln, cost_rel) for n, ln in sorted(params.items())]):
            tokens = set(name.lower().split("_")) | {name.lower()}
            if tokens & _BATCH_TOKENS:
                diags.append(self.diag(
                    "CIM207", Severity.ERROR,
                    f"batch/search execution knob {name!r} in the "
                    f"cache-key surface — batched evaluation is "
                    f"bit-identical by contract and search only picks "
                    f"which points run",
                    file=rel, line=lineno,
                    hint="put batching on SweepRunner (batch_size) and "
                         "search on SearchPolicy; a job's key must not "
                         "vary with how the sweep is dispatched, or "
                         "batched and per-point runs would stop sharing "
                         "one store"))
        for node in ast.walk(ctx.tree(job_path)):
            target = ""
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[:3] in (
                            [pkg, "explore", "batch"],
                            [pkg, "explore", "search"]):
                        target = alias.name
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level > 0:
                    names = {a.name for a in node.names}
                    if mod.split(".")[0] in ("batch", "search") or (
                            not mod and names & {"batch", "search"}):
                        target = f"{pkg}.explore.{mod or 'batch/search'}"
                elif mod.split(".")[:3] in ([pkg, "explore", "batch"],
                                            [pkg, "explore", "search"]):
                    target = mod
            if target:
                diags.append(self.diag(
                    "CIM207", Severity.ERROR,
                    f"explore/job.py imports {target} — the cache-key "
                    f"module must not depend on the batch/search "
                    f"execution layer",
                    file=job_rel, line=node.lineno,
                    hint="the dependency points the other way: batch.py "
                         "derives base keys FROM job.canonical; job.py "
                         "defines the memoisation contract and stays "
                         "dispatch-free by construction"))

        # CIM204 — CACHE_SCHEMA history entry for the current value
        schema = _schema_assignment(ctx.tree(job_path))
        if schema is None:
            diags.append(self._missing("CACHE_SCHEMA assignment", job_rel))
        else:
            value, lineno = schema
            entries = _history_entries(ctx.source_lines(job_path), lineno)
            if value not in entries:
                known = ", ".join(str(e) for e in sorted(entries)) or "none"
                diags.append(self.diag(
                    "CIM204", Severity.ERROR,
                    f"CACHE_SCHEMA = {value} has no matching '# {value}:' "
                    f"history entry (recorded: {known})",
                    file=job_rel, line=lineno,
                    hint="every schema bump documents what changed in the "
                         "comment block directly above the assignment"))
        return diags
