"""Pass 1 — import-boundary: the modeling plane must not reach jax.

Builds the module import graph purely from source (``ast`` — nothing is
imported or executed) and enforces the repo's layering contract:

* **Protected** (modeling plane, must import jax-free):
  ``repro.core``, ``repro.explore``, ``repro.trace``, ``repro.configs``,
  ``repro.calibrate``, ``repro.analysis``, ``repro.obs`` (the
  observability plane records modeling-plane runs and must stay
  importable on the jax-free CI interpreters).
* **Execution plane** (may import jax eagerly): everything else under
  ``repro`` — ``models``, ``kernels``, ``serve``, ``launch``, ``train``,
  ``runtime``, ``distributed``, ``sparsity``, ``data``.

Only *eager* imports count: module-scope and class-scope statements, the
bodies of module-scope ``if``/``try``/``with``.  Imports inside function
bodies are the declared lazy-site mechanism (``pruning.py``-style) and
are allowed — being inside a ``def`` is what *verifies* them lazy, since
nothing runs at import time.  ``if TYPE_CHECKING:`` blocks never execute
and are likewise exempt.

Codes
-----
* ``CIM101`` (error) — eager import of a forbidden root (``jax``,
  ``jaxlib``) from a protected module.
* ``CIM102`` (error) — eager import of a repro module that itself
  (transitively, via eager edges) reaches jax.
* ``CIM103`` (error) — eager import crossing the boundary: a protected
  module imports an execution-plane repro module at module scope.  Even
  if that module is jax-free today, the edge breaks the layering
  contract the jax-free CI jobs rely on.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Set, Tuple

from .diagnostics import Diagnostic, Severity
from .framework import AnalysisPass, PassContext, register

__all__ = ["ImportBoundaryPass", "PROTECTED_PREFIXES", "FORBIDDEN_ROOTS",
           "eager_imports", "build_eager_graph"]

# Prefixes of the jax-free modeling plane.  A module is protected when
# its dotted name equals a prefix or starts with "<prefix>.".
PROTECTED_PREFIXES: Tuple[str, ...] = (
    "repro.core", "repro.explore", "repro.trace",
    "repro.configs", "repro.calibrate", "repro.analysis", "repro.obs",
)

# Import roots the modeling plane must never reach eagerly.
FORBIDDEN_ROOTS: Tuple[str, ...] = ("jax", "jaxlib")


def is_protected(module: str) -> bool:
    return any(module == p or module.startswith(p + ".")
               for p in PROTECTED_PREFIXES)


@dataclasses.dataclass
class ImportSite:
    target: str        # dotted module the statement names
    lineno: int
    lazy: bool         # inside a function body (or TYPE_CHECKING guard)


def _is_type_checking_guard(node: ast.If) -> bool:
    t = node.test
    return ((isinstance(t, ast.Name) and t.id == "TYPE_CHECKING")
            or (isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING"))


def _resolve_from(module: str, node: ast.ImportFrom) -> List[str]:
    """Absolute candidate targets of a ``from X import a, b`` statement.

    For relative imports the base is computed from the importing
    module's package.  Each imported name is also emitted as a candidate
    submodule (``from ..core import workload`` reaches
    ``repro.core.workload`` when ``workload`` is a module)."""
    if node.level == 0:
        base = node.module or ""
    else:
        # strip the module's own leaf, then one package per extra dot
        parts = module.split(".")
        parts = parts[:len(parts) - node.level]
        if node.module:
            parts.append(node.module)
        base = ".".join(parts)
    if not base:
        return []
    out = [base]
    out.extend(f"{base}.{alias.name}" for alias in node.names
               if alias.name != "*")
    return out


def eager_imports(module: str, tree: ast.Module) -> List[ImportSite]:
    """Every import statement in ``tree`` with its laziness resolved."""
    sites: List[ImportSite] = []

    def visit(node: ast.AST, lazy: bool) -> None:
        for child in ast.iter_child_nodes(node):
            child_lazy = lazy
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                child_lazy = True
            elif isinstance(child, ast.If) and _is_type_checking_guard(child):
                child_lazy = True
            if isinstance(child, ast.Import):
                for alias in child.names:
                    sites.append(ImportSite(alias.name, child.lineno, lazy))
            elif isinstance(child, ast.ImportFrom):
                for target in _resolve_from(module, child):
                    sites.append(ImportSite(target, child.lineno, lazy))
            else:
                visit(child, child_lazy)

    visit(tree, lazy=False)
    return sites


def build_eager_graph(ctx: PassContext) -> Dict[str, List[ImportSite]]:
    """module -> eager import sites, for every module under src/repro."""
    graph: Dict[str, List[ImportSite]] = {}
    for module, path in ctx.iter_modules():
        sites = eager_imports(module, ctx.tree(path))
        graph[module] = [s for s in sites if not s.lazy]
    return graph


def _internal_target(target: str, modules: Set[str]) -> str:
    """Map an import target onto a known repro module (longest match),
    or '' when it is external."""
    parts = target.split(".")
    for i in range(len(parts), 0, -1):
        cand = ".".join(parts[:i])
        if cand in modules:
            return cand
    return ""


def _jax_tainted(graph: Dict[str, List[ImportSite]],
                 modules: Set[str]) -> Set[str]:
    """Modules whose *import* (not call) transitively executes a jax
    import — fixpoint over eager edges."""
    tainted = {m for m, sites in graph.items()
               if any(s.target.split(".")[0] in FORBIDDEN_ROOTS
                      for s in sites)}
    changed = True
    while changed:
        changed = False
        for m, sites in graph.items():
            if m in tainted:
                continue
            for s in sites:
                dep = _internal_target(s.target, modules)
                if dep and dep != m and dep in tainted:
                    tainted.add(m)
                    changed = True
                    break
    return tainted


@register
class ImportBoundaryPass(AnalysisPass):
    name = "import-boundary"
    codes = ("CIM101", "CIM102", "CIM103")
    description = ("modeling-plane modules (core/explore/trace/configs/"
                   "calibrate/analysis) must not reach jax or the "
                   "execution plane through eager imports")

    def run(self, ctx: PassContext) -> List[Diagnostic]:
        graph = build_eager_graph(ctx)
        modules = set(graph)
        tainted = _jax_tainted(graph, modules)
        diags: List[Diagnostic] = []
        for module in sorted(graph):
            if not is_protected(module):
                continue
            path = ctx.module_path(module)
            rel = ctx.rel(path) if path else module
            seen: Set[Tuple[str, int, str]] = set()
            for site in graph[module]:
                root = site.target.split(".")[0]
                dep = _internal_target(site.target, modules)
                finding = None
                if root in FORBIDDEN_ROOTS:
                    finding = ("CIM101",
                               f"protected module {module} eagerly imports "
                               f"{site.target}",
                               "move the import inside the function that "
                               "needs it (lazy site), or relocate this code "
                               "to the execution plane")
                elif dep and dep != module and dep in tainted:
                    finding = ("CIM102",
                               f"protected module {module} eagerly imports "
                               f"{dep}, which transitively imports jax",
                               f"break the eager chain: make the jax import "
                               f"in {dep} (or below) lazy")
                elif dep and dep != module and not is_protected(dep):
                    finding = ("CIM103",
                               f"protected module {module} eagerly imports "
                               f"execution-plane module {dep}",
                               "import it lazily inside the consuming "
                               "function, or move the shared code into the "
                               "modeling plane")
                if finding is None:
                    continue
                code, msg, hint = finding
                key = (code, site.lineno, dep or site.target)
                if key in seen:       # one report per statement/edge
                    continue
                seen.add(key)
                diags.append(self.diag(code, Severity.ERROR, msg,
                                       file=rel, line=site.lineno,
                                       hint=hint))
        return diags
