"""Pass framework: source discovery, AST cache, registry, runner.

A pass is a named check over the repository *source tree* (never over
imported modules — every pass here must run on a box without jax, and
must not execute the code it inspects).  Passes receive a
:class:`PassContext` rooted at the repo (or at a temporary mutated tree
in tests), read ASTs through its cache, and return
:class:`~repro.analysis.diagnostics.Diagnostic` lists.

Adding a pass: subclass :class:`AnalysisPass`, set ``name``/``codes``,
implement ``run``, and decorate with :func:`register`.  The CLI and
``run_passes`` pick it up automatically; document its codes in
``docs/analysis.md``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple, Type

from .diagnostics import Diagnostic, apply_suppressions

__all__ = ["PassContext", "AnalysisPass", "register", "all_passes",
           "get_pass", "run_passes"]


def _find_repo_root(start: Optional[Path] = None) -> Path:
    """Locate the directory containing ``src/repro`` (repo root).

    Works from an editable install (this file lives at
    ``<root>/src/repro/analysis/framework.py``) and from any CWD.
    """
    here = (start or Path(__file__).resolve()).parent
    for cand in (here, *here.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    # fall back to the package's grandparent (src/..) even if layout moved
    return Path(__file__).resolve().parents[3]


class PassContext:
    """Shared state for one analysis run: root paths + parsed-AST cache."""

    def __init__(self, root: Optional[Path] = None,
                 package: str = "repro") -> None:
        self.root = Path(root).resolve() if root else _find_repo_root()
        self.package = package
        self.src = self.root / "src" / package
        self._asts: Dict[str, Tuple[Path, ast.Module]] = {}
        self._sources: Dict[str, List[str]] = {}

    # -- discovery -----------------------------------------------------------

    def module_name(self, path: Path) -> str:
        """Dotted module name for a file under ``src/`` (pkg/__init__.py
        maps to the package itself)."""
        rel = path.relative_to(self.src.parent)
        parts = list(rel.with_suffix("").parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def iter_modules(self) -> Iterator[Tuple[str, Path]]:
        """All ``(module_name, path)`` pairs under ``src/<package>/``,
        sorted by name for deterministic diagnostic order."""
        pairs = [(self.module_name(p), p)
                 for p in sorted(self.src.rglob("*.py"))]
        return iter(sorted(pairs))

    # -- cached access --------------------------------------------------------

    def rel(self, path: Path) -> str:
        try:
            return str(path.relative_to(self.root))
        except ValueError:
            return str(path)

    def source_lines(self, path: Path) -> List[str]:
        key = self.rel(path)
        if key not in self._sources:
            self._sources[key] = path.read_text().splitlines()
        return self._sources[key]

    def tree(self, path: Path) -> ast.Module:
        key = self.rel(path)
        if key not in self._asts:
            text = "\n".join(self.source_lines(path))
            self._asts[key] = (path, ast.parse(text, filename=key))
        return self._asts[key][1]

    def module_tree(self, module: str) -> Optional[ast.Module]:
        path = self.module_path(module)
        return self.tree(path) if path else None

    def module_path(self, module: str) -> Optional[Path]:
        parts = module.split(".")
        if parts[0] != self.package:
            return None
        base = self.src.joinpath(*parts[1:])
        if (base / "__init__.py").is_file():
            return base / "__init__.py"
        if base.with_suffix(".py").is_file():
            return base.with_suffix(".py")
        return None

    @property
    def sources(self) -> Dict[str, List[str]]:
        return self._sources


class AnalysisPass:
    """Base class for one named check.  Subclasses set ``name``, the
    ``codes`` they can emit, and implement :meth:`run`."""

    name: str = ""
    codes: Tuple[str, ...] = ()
    description: str = ""

    def run(self, ctx: PassContext) -> List[Diagnostic]:
        raise NotImplementedError

    def diag(self, code: str, severity: str, message: str,
             **kw) -> Diagnostic:
        assert code in self.codes, f"{self.name} emitting undeclared {code}"
        return Diagnostic(code=code, severity=severity, message=message,
                          pass_name=self.name, **kw)


_REGISTRY: Dict[str, Type[AnalysisPass]] = {}


def register(cls: Type[AnalysisPass]) -> Type[AnalysisPass]:
    assert cls.name and cls.name not in _REGISTRY, cls
    _REGISTRY[cls.name] = cls
    return cls


def all_passes() -> Dict[str, Type[AnalysisPass]]:
    # import pass modules for side-effect registration (lazy so that
    # `import repro.analysis` stays cheap and cycle-free)
    from . import (cachekey_pass, determinism_pass,  # noqa: F401
                   imports_pass, modelplane_pass)
    return dict(_REGISTRY)


def get_pass(name: str) -> AnalysisPass:
    passes = all_passes()
    if name not in passes:
        known = ", ".join(sorted(passes))
        raise KeyError(f"unknown pass {name!r} (known: {known})")
    return passes[name]()


def run_passes(names: Optional[List[str]] = None,
               root: Optional[Path] = None) -> List[Diagnostic]:
    """Run the named passes (default: all, in registration order) over
    the tree at ``root``, apply suppressions, and return diagnostics."""
    ctx = PassContext(root=root)
    passes = all_passes()
    selected = names if names is not None else list(passes)
    diags: List[Diagnostic] = []
    for name in selected:
        diags.extend(get_pass(name).run(ctx))
    return apply_suppressions(diags, ctx.sources)
