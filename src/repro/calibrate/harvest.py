"""Sample harvesting: turn ledgers and microbenchmarks into fit inputs.

A calibration *sample* is one measured execution: how much work it did
(FLOPs, HBM bytes, interconnect bytes — per device) and how long it took
(wall seconds), tagged with an op class.  Two sources produce them:

* **Dry-run ledgers** (``repro.launch.dryrun`` JSONL): each record
  already carries per-device ``flops`` / ``bytes_accessed`` /
  ``collective_bytes``; any record that additionally has a measured
  time field (``time_s`` / ``wall_s`` / ``step_time_s``, written by a
  real execution of the same cell) becomes a sample of class
  ``step:<kind>``.  Records without a time are characterisation-only
  and are skipped (counted, not silently dropped).
* **Kernel microbenchmarks** (:func:`microbench_kernels`): wall-clock
  timings of the Pallas kernels' dispatch wrappers
  (``flash_attention`` / ``block_sparse_matmul`` /
  ``intrablock_gather_matmul``) and their pure-jnp ``ref`` oracles on
  whatever device jax sees, with analytically-counted FLOPs/bytes for
  the exact shapes run.  This is the only part of the subsystem that
  imports jax, and it does so lazily.

Sample JSONL is a superset of the dry-run ledger format, so
``python -m repro.calibrate fit --ledger`` accepts either file.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["Sample", "HarvestReport", "record_to_sample", "from_ledger",
           "read_samples", "write_samples", "microbench_kernels"]

_TIME_KEYS = ("time_s", "wall_s", "step_time_s")


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured execution, per device."""

    op_class: str
    flops: float
    bytes: float
    coll_bytes: float
    time_s: float
    meta: Tuple[Tuple[str, object], ...] = ()

    def to_record(self) -> Dict[str, object]:
        return {"op_class": self.op_class, "flops": self.flops,
                "bytes": self.bytes, "coll_bytes": self.coll_bytes,
                "time_s": self.time_s, "meta": dict(self.meta)}


@dataclasses.dataclass
class HarvestReport:
    """What a harvest pass produced — and what it had to leave behind."""

    samples: List[Sample]
    skipped_untimed: int = 0     # well-formed records with no time field
    skipped_malformed: int = 0   # undecodable / key-incomplete records

    def merged(self, other: "HarvestReport") -> "HarvestReport":
        return HarvestReport(
            samples=self.samples + other.samples,
            skipped_untimed=self.skipped_untimed + other.skipped_untimed,
            skipped_malformed=self.skipped_malformed + other.skipped_malformed)


def _coll_total(rec: Dict) -> float:
    coll = rec.get("collective_bytes", 0.0)
    if isinstance(coll, dict):
        return float(sum(v for k, v in coll.items() if k != "count"))
    return float(coll or 0.0)


def record_to_sample(rec: Dict) -> Optional[Sample]:
    """Normalise one JSONL record (sample-format or dry-run-ledger
    format) into a :class:`Sample`; ``None`` if it carries no timing."""
    if not isinstance(rec, dict) or "error" in rec:
        return None
    t = next((rec[k] for k in _TIME_KEYS if isinstance(rec.get(k), (int, float))
              and rec[k] > 0), None)
    if t is None:
        return None
    if "op_class" in rec:                      # native sample format
        flops, nbytes = rec.get("flops", 0.0), rec.get("bytes", 0.0)
        coll = float(rec.get("coll_bytes", 0.0) or 0.0)
        op_class = str(rec["op_class"])
        meta = rec.get("meta", {})
    elif "bytes_accessed" in rec:              # dry-run ledger format
        flops, nbytes = rec.get("flops", 0.0), rec["bytes_accessed"]
        coll = _coll_total(rec)
        op_class = f"step:{rec.get('kind', 'train')}"
        meta = {k: rec[k] for k in ("arch", "cell", "mesh", "tag", "chips")
                if k in rec}
    else:
        return None
    try:
        return Sample(op_class=op_class, flops=float(flops),
                      bytes=float(nbytes), coll_bytes=coll,
                      time_s=float(t),
                      meta=tuple(sorted((str(k), v) for k, v in meta.items())))
    except (TypeError, ValueError):
        return None


def _iter_records(path: Union[str, Path]):
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield line


def from_ledger(path: Union[str, Path]) -> HarvestReport:
    """Harvest every timed record of a JSONL ledger (either format)."""
    rep = HarvestReport(samples=[])
    for line in _iter_records(path):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            rep.skipped_malformed += 1
            continue
        s = record_to_sample(rec)
        if s is None:
            if isinstance(rec, dict) and not any(k in rec for k in _TIME_KEYS):
                rep.skipped_untimed += 1
            else:
                rep.skipped_malformed += 1
        else:
            rep.samples.append(s)
    return rep


def read_samples(path: Union[str, Path]) -> List[Sample]:
    return from_ledger(path).samples


def write_samples(samples: Sequence[Sample], path: Union[str, Path],
                  *, append: bool = True) -> Path:
    path = Path(path)
    if path.parent and str(path.parent) not in (".", ""):
        path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a" if append else "w") as f:
        for s in samples:
            f.write(json.dumps(s.to_record()) + "\n")
    return path


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (the only jax-touching corner of the subsystem)
# ---------------------------------------------------------------------------

def _time_call(fn, *args, repeats: int, **kw) -> float:
    """Best-of-``repeats`` wall seconds, after one warmup/compile call."""
    import jax

    jax.block_until_ready(fn(*args, **kw))     # compile + warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def microbench_kernels(*, sizes: Sequence[int] = (256, 512),
                       repeats: int = 3, impl: str = "auto",
                       seed: int = 0, log=sys.stderr) -> HarvestReport:
    """Time the kernel dispatch wrappers against their oracles.

    For each size ``S`` this runs, on whatever backend jax resolves
    (TPU → Pallas kernels, elsewhere → the jnp reference oracles, i.e.
    exactly the dispatch users get):

    * ``attention``  — fused flash attention over (1, S, 4, 64);
    * ``matmul``     — FullBlock block-sparse matmul, (S, S) @ (S, S)
      at 50% block sparsity, plus a dense ``jnp.dot`` of the same shape;
    * ``intrablock`` — row-aligned IntraBlock(4, 2) gather-matmul.

    FLOP/byte counts are the analytic counts for the shapes run, so the
    fitted peaks are *achieved* device rates — which is the point.
    """
    import numpy as np

    import jax
    import jax.numpy as jnp

    from ..kernels import ops

    rng = np.random.default_rng(seed)
    dev = jax.devices()[0]
    device = f"{dev.platform}:{getattr(dev, 'device_kind', '?')}"
    samples: List[Sample] = []

    def add(op_class, fn, *args, flops, nbytes, shape, **kw):
        try:
            t = _time_call(fn, *args, repeats=repeats, **kw)
        except Exception as e:  # noqa: BLE001 — one kernel failing must not
            print(f"calibrate: microbench {op_class}{shape} failed: "
                  f"{type(e).__name__}: {e}", file=log)
            return
        samples.append(Sample(
            op_class=op_class, flops=float(flops), bytes=float(nbytes),
            coll_bytes=0.0, time_s=t,
            meta=(("device", device), ("impl", ops._resolve(impl)),
                  ("repeats", repeats), ("shape", str(shape)))))

    for S in sizes:
        B, H, hd = 1, 4, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        # causal scores + weighted sum: 2 matmuls over the lower triangle
        att_flops = 2 * 2 * B * H * (S * S / 2) * hd
        att_bytes = 4 * (3 + 1) * B * S * H * hd
        add("attention", ops.flash_attention, q, k, v,
            causal=True, impl=impl, flops=att_flops, nbytes=att_bytes,
            shape=(B, S, H, hd))

        w = rng.standard_normal((S, S)).astype(np.float32)
        x = jnp.asarray(rng.standard_normal((128, S)), jnp.float32)
        bm = bn = max(32, S // 8)
        keep = rng.random((S // bm, S // bn)) < 0.5
        keep[0, :] = True                       # every column keeps ≥1 block
        w_comp, idx = ops.compress_fullblock(w, keep, bm, bn)
        kept = int(keep.sum())
        add("matmul", ops.block_sparse_matmul,
            x, jnp.asarray(w_comp), jnp.asarray(idx), impl=impl,
            flops=2 * 128 * bm * bn * kept,
            nbytes=4 * (128 * S + kept * bm * bn + 128 * S),
            shape=(128, S, f"{kept}blk"))
        add("matmul", jnp.dot, x, jnp.asarray(w),
            flops=2 * 128 * S * S, nbytes=4 * (128 * S + S * S + 128 * S),
            shape=(128, S, "dense"))

        m, phi = 4, 2
        pat = np.zeros((S // m, m), bool)
        for i in range(S // m):
            pat[i, rng.choice(m, size=phi, replace=False)] = True
        mask = np.repeat(pat[:, :, None], S, axis=2).reshape(S, S)
        wc, row_idx = ops.compress_intrablock(w, mask, m)
        add("intrablock", ops.intrablock_gather_matmul,
            x, jnp.asarray(wc), jnp.asarray(row_idx), impl=impl,
            flops=2 * 128 * wc.shape[0] * S,
            nbytes=4 * (128 * S + wc.size + 128 * S),
            shape=(128, S, f"{m}:{phi}"))

    return HarvestReport(samples=samples)
