"""Calibration profiles: measured roofline parameters as data.

A :class:`CalibrationProfile` is the contract between the measurement
plane (dry-run ledgers + kernel microbenchmarks, :mod:`.harvest`) and
the consumers that price work against a device:

* :mod:`repro.launch.roofline` resolves its per-chip peaks from a
  profile instead of module constants;
* :func:`repro.core.costmodel.simulate` optionally scales op latency by
  the profile's per-op-class efficiency factors;
* the exploration engine threads a profile through every job so sweeps
  rank designs by *calibrated* peaks.

Profiles are schema-versioned JSON documents with provenance (where the
samples came from) and fit residuals (how well the roofline explains
them), and are persisted content-addressed — the filename embeds a
digest of the physical parameters, so two fits that agree land on the
same file and a changed fit never silently shadows an old one.

This module is stdlib-only on purpose: everything that merely *reads* a
profile (roofline, the explore CLI) must keep working without jax, and
without even numpy.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from pathlib import Path
from typing import Dict, Optional, Union

__all__ = [
    "SCHEMA_VERSION", "DEFAULT_PROFILE_NAME", "ProfileError",
    "CalibrationProfile", "default_profile", "resolve_profile",
    "bundled_profiles_dir",
]

SCHEMA_VERSION = 1

# The analytic TPU v5e-class numbers the repo shipped with (see
# repro/launch/roofline.py).  The bundled default profile carries exactly
# these values so profile-backed code paths reproduce pre-calibration
# output bit-for-bit.
DEFAULT_PROFILE_NAME = "tpu-v5e-analytic"
_DEFAULT_PEAK_FLOPS = 197e12
_DEFAULT_HBM_BW = 819e9
_DEFAULT_ICI_BW = 50e9


class ProfileError(ValueError):
    """A profile document failed schema validation."""


def _positive(name: str, v) -> float:
    if not isinstance(v, (int, float)) or isinstance(v, bool) \
            or not math.isfinite(v) or v <= 0:
        raise ProfileError(f"profile field {name!r} must be a finite "
                           f"positive number, got {v!r}")
    return float(v)


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Measured (or analytic) roofline parameters for one device class.

    ``peak_flops`` / ``hbm_bw`` / ``ici_bw`` are per-chip peaks in
    FLOP/s, bytes/s and bytes/s/link.  ``efficiency`` maps an op-class
    name (``"matmul"``, ``"attention"``, ``"post_proc"``, ...) to the
    fraction of the fitted roofline that class actually achieves —
    1.0 means the class sits on the roofline, 0.5 means it runs at half
    of it (latency doubles).  ``provenance`` records where the fit's
    samples came from; ``residuals`` records per-class relative fit
    error.  Both are informational: they travel with the profile but do
    not enter :meth:`content_hash`.
    """

    name: str
    device: str
    peak_flops: float = _DEFAULT_PEAK_FLOPS
    hbm_bw: float = _DEFAULT_HBM_BW
    ici_bw: float = _DEFAULT_ICI_BW
    efficiency: Dict[str, float] = dataclasses.field(default_factory=dict)
    provenance: Dict[str, object] = dataclasses.field(default_factory=dict)
    residuals: Dict[str, float] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    # -- validation ---------------------------------------------------------
    def validate(self) -> "CalibrationProfile":
        if self.schema_version != SCHEMA_VERSION:
            raise ProfileError(
                f"unsupported profile schema_version={self.schema_version!r} "
                f"(this build reads version {SCHEMA_VERSION})")
        if not self.name or not isinstance(self.name, str):
            raise ProfileError(f"profile name must be a non-empty string, "
                               f"got {self.name!r}")
        if not isinstance(self.device, str):
            raise ProfileError(f"profile device must be a string, "
                               f"got {self.device!r}")
        _positive("peak_flops", self.peak_flops)
        _positive("hbm_bw", self.hbm_bw)
        _positive("ici_bw", self.ici_bw)
        if not isinstance(self.efficiency, dict):
            raise ProfileError("efficiency must be a dict of op-class → "
                               f"factor, got {type(self.efficiency).__name__}")
        for k, v in self.efficiency.items():
            _positive(f"efficiency[{k!r}]", v)
            if v > 4.0:
                raise ProfileError(
                    f"efficiency[{k!r}]={v} is implausible (> 4× the fitted "
                    "roofline); the fit is broken or the sample mislabelled")
        return self

    # -- lookups ------------------------------------------------------------
    def efficiency_for(self, op_class: str) -> float:
        """Efficiency factor for an op class; unknown classes ride the
        roofline (1.0) so an uncalibrated class never shifts results."""
        return float(self.efficiency.get(op_class, 1.0))

    def is_analytic_default(self) -> bool:
        """True when the physical content matches the shipped analytic
        numbers exactly (i.e. applying it is a no-op)."""
        return (self.peak_flops == _DEFAULT_PEAK_FLOPS
                and self.hbm_bw == _DEFAULT_HBM_BW
                and self.ici_bw == _DEFAULT_ICI_BW
                and all(v == 1.0 for v in self.efficiency.values()))

    # -- (de)serialisation --------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "CalibrationProfile":
        if not isinstance(d, dict):
            raise ProfileError(f"profile document must be a JSON object, "
                               f"got {type(d).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ProfileError(f"unknown profile fields: {sorted(unknown)}")
        missing = {"name", "device"} - set(d)
        if missing:
            raise ProfileError(f"profile missing required fields: "
                               f"{sorted(missing)}")
        return cls(**d).validate()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        if path.parent and str(path.parent) not in (".", ""):
            os.makedirs(path.parent, exist_ok=True)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path: Union[str, Path]) -> "CalibrationProfile":
        try:
            doc = json.loads(Path(path).read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise ProfileError(f"cannot read profile {path}: {e}") from e
        return cls.from_dict(doc)

    # -- content addressing -------------------------------------------------
    def content_hash(self) -> str:
        """Digest over the *physical* parameters only.

        Name, device, provenance and residuals are metadata about where
        the numbers came from; two fits that land on the same peaks and
        efficiencies are the same profile for every consumer — they must
        share an address (and a sweep-cache key, see
        ``repro.explore.job.canonical``).
        """
        payload = json.dumps(
            ["calibration-profile", self.schema_version,
             repr(float(self.peak_flops)), repr(float(self.hbm_bw)),
             repr(float(self.ici_bw)),
             sorted((k, repr(float(v))) for k, v in self.efficiency.items())],
            separators=(",", ":"), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()

    def save_addressed(self, profiles_dir: Union[str, Path]) -> Path:
        """Persist under ``<dir>/<name>-<hash12>.json`` (content-addressed)."""
        digest = self.content_hash()[:12]
        return self.save(Path(profiles_dir) / f"{self.name}-{digest}.json")


# ---------------------------------------------------------------------------
# Bundled default + resolution
# ---------------------------------------------------------------------------

def bundled_profiles_dir() -> Path:
    return Path(__file__).resolve().parent / "profiles"


def default_profile() -> CalibrationProfile:
    """The bundled analytic profile (exactly the legacy roofline
    constants), loaded from the packaged JSON so the offline path and the
    file format exercise the same code."""
    path = bundled_profiles_dir() / "default.json"
    try:
        prof = CalibrationProfile.load(path)
    except ProfileError:
        # Source checkout without package data (or a mangled install):
        # fall back to the in-code twin of the same numbers.
        prof = CalibrationProfile(name=DEFAULT_PROFILE_NAME,
                                  device="tpu-v5e (analytic)")
    if not prof.is_analytic_default():
        raise ProfileError(
            "bundled default.json no longer matches the analytic constants; "
            "default-profile output would silently shift")
    return prof


def resolve_profile(spec: Union[None, str, Path, CalibrationProfile]
                    ) -> CalibrationProfile:
    """Turn a CLI-ish profile spec into a profile.

    ``None`` or ``"default"`` → the bundled analytic profile; a
    :class:`CalibrationProfile` passes through; anything else is a path.
    """
    if spec is None or (isinstance(spec, str) and spec == "default"):
        return default_profile()
    if isinstance(spec, CalibrationProfile):
        return spec.validate()
    return CalibrationProfile.load(spec)
