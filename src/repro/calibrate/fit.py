"""Fit roofline parameters to harvested samples.

The model is the additive roofline in *inverse-peak* space: for sample
``i`` with per-device work ``(f_i FLOPs, b_i bytes, c_i collective
bytes)`` and measured wall time ``t_i``,

    t_i  ≈  f_i·θ_F + b_i·θ_B + c_i·θ_I ,   θ = (1/peak_flops,
                                                 1/hbm_bw, 1/ici_bw)

which is linear in θ, so calibration is a *bounded* least-squares
problem (peaks are physical: θ must stay inside
``1/upper ≤ θ ≤ 1/lower``).  Rows are scaled by ``1/t_i`` so every
sample counts by relative error, not absolute seconds — a 40 µs kernel
and a 400 ms training step pull equally.

After the global fit, each op class gets an *efficiency factor*: the
median ratio of roofline-predicted to measured time over that class's
samples.  Classes that sit on the fitted roofline get 1.0; a class
running at half the roofline gets 0.5 (its modeled latency doubles when
the profile is applied).

Solver: ``scipy.optimize.lsq_linear`` when scipy is importable (it is
not a declared dependency), else a deterministic projected-gradient
fallback in pure numpy — the problem is 3-dimensional, so a few
thousand Lipschitz-step iterations converge to machine precision.
"""
from __future__ import annotations

import math
import statistics
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .harvest import Sample
from .profile import CalibrationProfile, default_profile

__all__ = ["FitError", "PEAK_BOUNDS", "fit_profile", "bounded_lsq"]


class FitError(ValueError):
    """The sample set cannot support a fit."""


# Physical plausibility bounds per peak, (lower, upper).  Wide on
# purpose: they exist to keep the solver out of degenerate corners
# (θ → 0 ⇒ infinite peak), not to encode device knowledge.
PEAK_BOUNDS: Dict[str, Tuple[float, float]] = {
    "peak_flops": (1e6, 1e19),
    "hbm_bw": (1e5, 1e16),
    "ici_bw": (1e4, 1e15),
}

_EFF_CLIP = (0.05, 2.0)   # efficiency factors outside this are fit noise


def _pgd_lsq(A: np.ndarray, y: np.ndarray, lb: np.ndarray, ub: np.ndarray,
             iters: int = 20000) -> np.ndarray:
    """Projected-gradient bounded least squares (numpy fallback)."""
    AtA, Aty = A.T @ A, A.T @ y
    lip = float(np.linalg.norm(AtA, 2))
    x = np.clip(np.linalg.lstsq(A, y, rcond=None)[0], lb, ub)
    step = 1.0 / max(lip, 1e-300)
    for _ in range(iters):
        x_new = np.clip(x - step * (AtA @ x - Aty), lb, ub)
        if np.allclose(x_new, x, rtol=0.0, atol=1e-18):
            break
        x = x_new
    return x


def bounded_lsq(A: np.ndarray, y: np.ndarray, lb: np.ndarray, ub: np.ndarray,
                *, solver: str = "auto") -> Tuple[np.ndarray, str]:
    """``min ‖Ax − y‖₂  s.t. lb ≤ x ≤ ub``; returns (x, solver-used)."""
    if solver not in ("auto", "scipy", "numpy"):
        raise ValueError(f"unknown solver {solver!r}")
    if solver in ("auto", "scipy"):
        try:
            from scipy.optimize import lsq_linear
        except ImportError:
            if solver == "scipy":
                raise
        else:
            res = lsq_linear(A, y, bounds=(lb, ub), method="bvls"
                             if A.shape[0] >= A.shape[1] else "trf")
            return np.asarray(res.x, dtype=float), "scipy"
    return _pgd_lsq(A, y, lb, ub), "numpy"


def fit_profile(samples: Sequence[Sample], *, name: str,
                device: Optional[str] = None,
                prior: Optional[CalibrationProfile] = None,
                solver: str = "auto",
                provenance: Optional[Dict[str, object]] = None
                ) -> CalibrationProfile:
    """Fit a :class:`CalibrationProfile` to harvested samples.

    ``prior`` (default: the bundled analytic profile) supplies the value
    of any peak the samples cannot identify — e.g. single-host
    microbenchmarks move zero collective bytes, so the ICI peak keeps
    its prior instead of drifting to a bound.
    """
    samples = [s for s in samples
               if s.time_s > 0 and math.isfinite(s.time_s)
               and (s.flops > 0 or s.bytes > 0 or s.coll_bytes > 0)]
    if not samples:
        raise FitError("no usable samples: every record lacked timing or "
                       "carried zero work")
    prior = prior or default_profile()
    dev = device or next(
        (str(dict(s.meta).get("device")) for s in samples
         if dict(s.meta).get("device")), prior.device)

    A = np.array([[s.flops, s.bytes, s.coll_bytes] for s in samples],
                 dtype=float)
    t = np.array([s.time_s for s in samples], dtype=float)
    Aw = A / t[:, None]                       # rows in relative-error scale
    yw = np.ones_like(t)

    keys = ("peak_flops", "hbm_bw", "ici_bw")
    lb = np.array([1.0 / PEAK_BOUNDS[k][1] for k in keys])
    ub = np.array([1.0 / PEAK_BOUNDS[k][0] for k in keys])
    identifiable = np.array([bool(np.any(A[:, j] > 0)) for j in range(3)])
    prior_theta = np.array([1.0 / prior.peak_flops, 1.0 / prior.hbm_bw,
                            1.0 / prior.ici_bw])

    cols = np.flatnonzero(identifiable)
    theta = prior_theta.copy()
    used = "prior"
    if len(cols):
        sub, used = bounded_lsq(Aw[:, cols], yw, lb[cols], ub[cols],
                                solver=solver)
        theta[cols] = sub
    peaks = {k: float(1.0 / theta[j]) for j, k in enumerate(keys)}

    # -- per-op-class efficiency vs the fitted roofline ---------------------
    pred = A @ theta
    by_class: Dict[str, list] = {}
    for s, p in zip(samples, pred):
        by_class.setdefault(s.op_class, []).append(p / s.time_s)
    efficiency = {
        c: float(min(max(statistics.median(r), _EFF_CLIP[0]), _EFF_CLIP[1]))
        for c, r in sorted(by_class.items())}

    # -- residuals (relative, after class efficiency) -----------------------
    rel = np.array([
        (pred[i] / efficiency[s.op_class] - s.time_s) / s.time_s
        for i, s in enumerate(samples)])
    residuals: Dict[str, float] = {
        "rel_rmse": float(np.sqrt(np.mean(rel ** 2))),
        "rel_max_abs": float(np.max(np.abs(rel))),
        "n_samples": float(len(samples)),
    }
    for c in by_class:
        sel = np.array([s.op_class == c for s in samples])
        residuals[f"rel_rmse:{c}"] = float(np.sqrt(np.mean(rel[sel] ** 2)))

    prov: Dict[str, object] = {
        "solver": used,
        "n_samples": len(samples),
        "classes": {c: len(r) for c, r in sorted(by_class.items())},
        "identified": [k for j, k in enumerate(keys) if identifiable[j]],
        "prior": prior.name,
    }
    prov.update(provenance or {})

    return CalibrationProfile(
        name=name, device=dev,
        peak_flops=peaks["peak_flops"], hbm_bw=peaks["hbm_bw"],
        ici_bw=peaks["ici_bw"], efficiency=efficiency,
        provenance=prov, residuals=residuals).validate()
