"""``python -m repro.calibrate`` — harvest → fit → inspect profiles.

Subcommands::

    collect  harvest samples (kernel microbenchmarks and/or timed
             ledger records) into a samples JSONL
    fit      bounded least-squares roofline fit over one or more
             sample/ledger files → a CalibrationProfile JSON
    show     print (and validate) a profile; --json for the raw document
    diff     compare two profiles' peaks and efficiencies

Examples::

    python -m repro.calibrate collect --kernels --out results/calib.jsonl
    python -m repro.calibrate fit --ledger results/calib.jsonl \
        --name my-host --out results/profile.json
    python -m repro.calibrate show results/profile.json
    python -m repro.calibrate diff results/profile.json default
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .fit import FitError, fit_profile
from .harvest import HarvestReport, from_ledger, write_samples
from .profile import CalibrationProfile, ProfileError, resolve_profile

_PEAKS = (("peak_flops", "FLOP/s"), ("hbm_bw", "B/s"), ("ici_bw", "B/s/link"))


def _fmt_si(v: float) -> str:
    for scale, suffix in ((1e15, "P"), (1e12, "T"), (1e9, "G"), (1e6, "M")):
        if v >= scale:
            return f"{v / scale:.3g} {suffix}"
    return f"{v:.3g} "


def _harvest_many(paths: List[str]) -> HarvestReport:
    rep = HarvestReport(samples=[])
    for p in paths:
        rep = rep.merged(from_ledger(p))
    return rep


def _report_skips(rep: HarvestReport) -> None:
    if rep.skipped_untimed or rep.skipped_malformed:
        print(f"calibrate: skipped {rep.skipped_untimed} untimed and "
              f"{rep.skipped_malformed} malformed record(s)", file=sys.stderr)


def _cmd_collect(args) -> int:
    rep = _harvest_many(args.ledger)
    if args.kernels:
        from .harvest import microbench_kernels
        sizes = [int(t) for t in args.sizes.split(",") if t]
        rep = rep.merged(microbench_kernels(
            sizes=sizes, repeats=args.repeats, impl=args.impl))
    _report_skips(rep)
    if not rep.samples:
        print("calibrate: nothing harvested (no --kernels and no timed "
              "ledger records)", file=sys.stderr)
        return 1
    write_samples(rep.samples, args.out, append=not args.fresh)
    classes = {}
    for s in rep.samples:
        classes[s.op_class] = classes.get(s.op_class, 0) + 1
    print(f"wrote {len(rep.samples)} sample(s) to {args.out} "
          f"({', '.join(f'{c}×{n}' for c, n in sorted(classes.items()))})")
    return 0


def _cmd_fit(args) -> int:
    rep = _harvest_many(args.ledger)
    _report_skips(rep)
    try:
        prof = fit_profile(
            rep.samples, name=args.name, device=args.device,
            solver=args.solver,
            provenance={"sources": list(args.ledger)})
    except FitError as e:
        print(f"calibrate: fit failed: {e}", file=sys.stderr)
        return 1
    if args.out:
        prof.save(args.out)
        print(f"wrote profile to {args.out}")
    if args.profiles_dir:
        path = prof.save_addressed(args.profiles_dir)
        print(f"wrote content-addressed copy to {path}")
    _print_profile(prof)
    return 0


def _print_profile(prof: CalibrationProfile) -> None:
    print(f"profile {prof.name!r}  (device: {prof.device}, "
          f"schema v{prof.schema_version}, hash {prof.content_hash()[:12]})")
    for key, unit in _PEAKS:
        print(f"  {key:<11} {_fmt_si(getattr(prof, key))}{unit}")
    for c, e in sorted(prof.efficiency.items()):
        print(f"  efficiency[{c}] = {e:.3f}")
    for k, v in sorted(prof.residuals.items()):
        print(f"  residual {k} = {v:.4g}")
    n = prof.provenance.get("n_samples")
    if n is not None:
        print(f"  fitted from {n} sample(s) via "
              f"{prof.provenance.get('solver', '?')} solver")


def _cmd_show(args) -> int:
    prof = resolve_profile(args.profile)
    if args.json:
        print(json.dumps(prof.to_dict(), indent=2, sort_keys=True))
    else:
        _print_profile(prof)
    if args.check:
        # load() already validated; round-trip the document too
        CalibrationProfile.from_dict(json.loads(prof.to_json()))
        print("OK: schema-valid, round-trips")
    return 0


def _cmd_diff(args) -> int:
    a, b = resolve_profile(args.a), resolve_profile(args.b)
    print(f"{'':<14}{a.name:>16}{b.name:>16}{'b/a':>10}")
    for key, _unit in _PEAKS:
        va, vb = getattr(a, key), getattr(b, key)
        print(f"{key:<14}{_fmt_si(va):>16}{_fmt_si(vb):>16}{vb / va:>10.3f}")
    for c in sorted(set(a.efficiency) | set(b.efficiency)):
        ea, eb = a.efficiency_for(c), b.efficiency_for(c)
        print(f"eff[{c}]".ljust(14) + f"{ea:>16.3f}{eb:>16.3f}"
              f"{eb / ea:>10.3f}")
    same = a.content_hash() == b.content_hash()
    print("identical physical content (peaks + efficiencies)"
          if same else "profiles differ")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calibrate", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("collect", help="harvest calibration samples")
    c.add_argument("--ledger", action="append", default=[],
                   help="JSONL ledger/sample file to ingest (repeatable)")
    c.add_argument("--kernels", action="store_true",
                   help="run the kernel microbenchmarks (needs jax)")
    c.add_argument("--sizes", default="256,512",
                   help="comma-separated matrix/sequence sizes")
    c.add_argument("--repeats", type=int, default=3)
    c.add_argument("--impl", default="auto",
                   choices=("auto", "ref", "pallas", "pallas_interpret"))
    c.add_argument("--out", default="results/calib_samples.jsonl")
    c.add_argument("--fresh", action="store_true",
                   help="overwrite --out instead of appending")
    c.set_defaults(fn=_cmd_collect)

    f = sub.add_parser("fit", help="fit a profile to samples")
    f.add_argument("--ledger", action="append", required=True,
                   help="sample/ledger JSONL (repeatable)")
    f.add_argument("--name", default="fitted")
    f.add_argument("--device", default=None)
    f.add_argument("--solver", default="auto",
                   choices=("auto", "scipy", "numpy"))
    f.add_argument("--out", default=None, help="profile JSON output path")
    f.add_argument("--profiles-dir", default=None,
                   help="also save a content-addressed copy here")
    f.set_defaults(fn=_cmd_fit)

    s = sub.add_parser("show", help="print and validate a profile")
    s.add_argument("profile", help="profile path, or 'default'")
    s.add_argument("--json", action="store_true")
    s.add_argument("--check", action="store_true",
                   help="assert the document round-trips the schema")
    s.set_defaults(fn=_cmd_show)

    d = sub.add_parser("diff", help="compare two profiles")
    d.add_argument("a", help="profile path, or 'default'")
    d.add_argument("b", help="profile path, or 'default'")
    d.set_defaults(fn=_cmd_diff)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except ProfileError as e:
        print(f"calibrate: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
