"""Calibration subsystem: close the model↔execution loop.

The analytic plane (:mod:`repro.core`) prices designs against assumed
peaks; the execution plane (:mod:`repro.launch`, :mod:`repro.kernels`)
measures real programs.  This package connects them:

* :mod:`.harvest` — collect samples from dry-run ledgers and Pallas
  kernel microbenchmarks;
* :mod:`.fit`     — bounded least-squares roofline fit over the samples;
* :mod:`.profile` — schema-versioned, content-addressed
  ``CalibrationProfile`` JSONs (with a bundled analytic default so
  everything works offline);

and the consumers apply them: ``repro.launch.roofline`` resolves its
peaks from a profile, ``repro.core.costmodel.simulate`` accepts one to
scale latency/energy, and ``python -m repro.explore --profile`` runs
calibrated sweeps.

CLI: ``python -m repro.calibrate {collect,fit,show,diff}``.
"""
from .profile import (DEFAULT_PROFILE_NAME, SCHEMA_VERSION,
                      CalibrationProfile, ProfileError, bundled_profiles_dir,
                      default_profile, resolve_profile)

__all__ = [
    "CalibrationProfile", "ProfileError", "SCHEMA_VERSION",
    "DEFAULT_PROFILE_NAME", "default_profile", "resolve_profile",
    "bundled_profiles_dir",
    "Sample", "HarvestReport", "record_to_sample", "from_ledger",
    "read_samples", "write_samples", "microbench_kernels",
    "FitError", "PEAK_BOUNDS", "bounded_lsq", "fit_profile",
]

# .fit pulls in numpy and .harvest can reach for jax; profile *reading*
# (roofline, the explore CLI) must stay stdlib-only, so those two
# modules resolve lazily on first attribute access (PEP 562).
_LAZY = {name: ".fit" for name in
         ("FitError", "PEAK_BOUNDS", "bounded_lsq", "fit_profile")}
_LAZY.update({name: ".harvest" for name in
              ("Sample", "HarvestReport", "record_to_sample", "from_ledger",
               "read_samples", "write_samples", "microbench_kernels")})


def __getattr__(name: str):
    if name in _LAZY:
        from importlib import import_module

        value = getattr(import_module(_LAZY[name], __name__), name)
        globals()[name] = value      # cache: __getattr__ runs once per name
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
