"""CIMinus quickstart — the paper's workflow in ~60 lines.

Describe a digital SRAM-CIM architecture, a sparse DNN workload, and a
mapping; run the cost model; read the energy/latency report.  Then walk
the same FlexBlock spec through the pruning workflow to see the actual
masks it generates.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (compare, default_mapping, dense_baseline,
                        flexblock_mask, hybrid, prune_matrix, resnet18,
                        row_block, simulate, usecase_arch)


def main():
    # 1. Hardware description (§IV-C): the paper's §VII architecture —
    #    4 macros of 1024×32 with 32×32 sub-arrays, 8-bit, preset energies.
    arch = usecase_arch(4, input_sparsity=False)
    print(f"CIM architecture: {arch.name}, macros={arch.org}, "
          f"macro={arch.macro.rows}x{arch.macro.cols}")

    # 2. Workload description: ResNet-18 (CIFAR-scale) as an op DAG,
    #    with FlexBlock sparsity — IntraBlock(2,1) 1:2 + FullBlock(2,16)
    #    row-block at overall 80 % (SDP-style hybrid, Table II).
    spec = hybrid(2, 16, 0.8)
    wl = resnet18(32).set_sparsity(spec)
    print(f"workload: {wl}")
    print(f"sparsity: {spec.name}")

    # 3. Mapping description: weight-stationary, duplicated across macros.
    mapping = default_mapping(arch, "duplicate")

    # 4. Cost model (§V): latency + per-unit energy, vs the dense baseline.
    rep = simulate(arch, wl, mapping)
    dense = dense_baseline(arch, wl, mapping)
    c = compare(rep, dense)
    print(f"\nlatency       : {rep.latency_ms:.4f} ms "
          f"(dense {dense.latency_ms:.4f} ms → {c['speedup']:.2f}x)")
    print(f"energy        : {rep.total_energy_uj:.2f} uJ "
          f"(dense {dense.total_energy_uj:.2f} uJ → "
          f"{c['energy_saving']:.2f}x saving)")
    print(f"array util    : {rep.utilization:.1%}")
    print(f"index storage : {rep.index_storage_bits / 8 / 1024:.1f} KiB")
    print("energy breakdown:")
    tot = sum(rep.grouped_energy().values())
    for grp, pj in sorted(rep.grouped_energy().items()):
        print(f"  {grp:10s} {pj / max(tot, 1e-9):6.1%}")

    # 5. Pruning workflow (§IV-D): the same spec on a real weight matrix.
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 48)).astype(np.float32)
    mask = flexblock_mask(jnp.asarray(w), spec, "l1")
    res = prune_matrix(jnp.asarray(w), spec)
    print(f"\npruning a 64x48 matrix with {spec.name}:")
    print(f"  density {res.density:.3f} (target {1 - 0.8:.3f}), "
          f"mask shape {mask.shape}")
    kept = np.abs(w * mask).sum() / np.abs(w).sum()
    print(f"  |W| L1 mass preserved: {kept:.1%}")


if __name__ == "__main__":
    main()
