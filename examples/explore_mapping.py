"""Mapping-strategy exploration (paper §VII-C) as an interactive script.

Sweeps mapping strategy (spatial weight-unroll vs weight duplication) ×
macro organisation (8×2 / 4×4 / 2×8) × weight rearrangement for a sparse
ResNet-50 on a 16-macro CIM architecture through the
:mod:`repro.explore` engine, and prints the trade-off table, the
latency/energy Pareto frontier, and the engine's cache accounting that
back the paper's Finding 2.

Run:  PYTHONPATH=src python examples/explore_mapping.py \
          [--model resnet50|vgg16] [--workers N]
"""
import argparse

from repro.core import hybrid, resnet50, usecase_arch, vgg16
from repro.explore import SweepRunner, mapping_sweep


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=["resnet50", "vgg16"],
                    default="resnet50")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: one per CPU)")
    args = ap.parse_args()
    wl_fn = {"resnet50": lambda: resnet50(32),
             "vgg16": lambda: vgg16(32)}[args.model]
    spec = hybrid(2, 16, 0.8)
    runner = SweepRunner(workers=args.workers)

    # one grid: strategy × organisation × rearrangement
    result = mapping_sweep(
        lambda org: usecase_arch(16, org), wl_fn, spec,
        orgs=((8, 2), (4, 4), (2, 8)),
        strategies=("spatial", "duplicate"),
        rearrange=(None, "slice"),
        runner=runner)

    print(f"{args.model} × IntraBlock(2,1)+FullBlock(2,16) @ 80% "
          f"on 16-macro CIM\n")
    hdr = f"{'org':>5} {'strategy':>10} {'rearrange':>10} {'latency ms':>11} " \
          f"{'energy uJ':>10} {'util':>6} {'speedup':>8}"
    print(hdr)
    print("-" * len(hdr))
    for r in result.rows:
        print(f"{r['org']:>5} {r['mapping']:>10} {r['rearrange']:>10} "
              f"{r['latency_ms']:>11.4f} {r['energy_uj']:>10.2f} "
              f"{r['utilization']:>6.1%} {r['speedup']:>8.2f}")

    best = result.top_k("latency_ms", 1)[0]
    print(f"\nbest: {best['mapping']} @ {best['org']} "
          f"(rearrange={best['rearrange']}, {best['latency_ms']:.4f} ms)")

    front = result.pareto((("latency_ms", "min"), ("energy_uj", "min")))
    print("\nlatency/energy Pareto frontier:")
    for r in front:
        print(f"  {r['mapping']:>10} @ {r['org']} rearrange={r['rearrange']:<6} "
              f"{r['latency_ms']:.4f} ms  {r['energy_uj']:.2f} uJ")

    s = result.stats
    print(f"\nengine: {s.requested} jobs, {s.unique} unique, "
          f"{s.cache_hits} cache hits, {s.evaluated} evaluated "
          f"on {s.workers} worker(s) in {s.wall_s:.2f}s")


if __name__ == "__main__":
    main()
