"""Mapping-strategy exploration (paper §VII-C) as an interactive script.

Sweeps mapping strategy (spatial weight-unroll vs weight duplication) ×
macro organisation (8×2 / 4×4 / 2×8) × weight rearrangement for a sparse
ResNet-50 on a 16-macro CIM architecture, and prints the trade-off table
that backs the paper's Finding 2.

Run:  PYTHONPATH=src python examples/explore_mapping.py [--model resnet50|vgg16]
"""
import argparse

from repro.core import (default_mapping, dense_baseline, hybrid, compare,
                        resnet50, simulate, sweep_mappings, usecase_arch,
                        vgg16)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=["resnet50", "vgg16"],
                    default="resnet50")
    args = ap.parse_args()
    wl_fn = {"resnet50": lambda: resnet50(32),
             "vgg16": lambda: vgg16(32)}[args.model]
    spec = hybrid(2, 16, 0.8)

    rows = sweep_mappings(lambda org: usecase_arch(16, org), wl_fn, spec,
                          orgs=((8, 2), (4, 4), (2, 8)),
                          strategies=("spatial", "duplicate"))
    print(f"{args.model} × IntraBlock(2,1)+FullBlock(2,16) @ 80% "
          f"on 16-macro CIM\n")
    hdr = f"{'org':>5} {'strategy':>10} {'latency ms':>11} " \
          f"{'energy uJ':>10} {'util':>6} {'speedup':>8}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['org']:>5} {r['mapping']:>10} {r['latency_ms']:>11.4f} "
              f"{r['energy_uj']:>10.2f} {r['utilization']:>6.1%} "
              f"{r['speedup']:>8.2f}")

    best = min(rows, key=lambda r: r["latency_ms"])
    print(f"\nbest: {best['mapping']} @ {best['org']} "
          f"({best['latency_ms']:.4f} ms)")

    # rearrangement study at the balanced 4×4 organisation
    print("\nweight rearrangement (4×4, duplicate):")
    arch = usecase_arch(16, (4, 4))
    dense = dense_baseline(arch, wl_fn(), default_mapping(arch, "duplicate"))
    for rr, label in ((None, "as-compressed"), ("slice", "rearranged")):
        mapping = default_mapping(arch, "duplicate", rearrange=rr,
                                  slice_size=arch.macro.sub_rows if rr else 0)
        rep = simulate(arch, wl_fn().set_sparsity(spec), mapping)
        c = compare(rep, dense)
        print(f"  {label:14s} util {rep.utilization:.1%}  "
              f"energy {rep.total_energy_uj:.2f} uJ  "
              f"speedup {c['speedup']:.2f}x")


if __name__ == "__main__":
    main()
