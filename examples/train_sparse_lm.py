"""End-to-end driver: prune an LM with FlexBlock, train it sparse, cost
it on a CIM architecture — the paper's full workflow on the execution
plane.

Pipeline:
  1. build a llama-family LM (default ~20M params for CPU speed;
     ``--full`` switches to the ~110M configuration),
  2. prune its weights with a hybrid IntraBlock(2,1)+FullBlock(2,16)
     FlexBlock spec at 50 %,
  3. sparse fine-tune with masked AdamW (pruned weights stay zero),
     fault-tolerant Trainer (checkpoint/restart, straggler log,
     NaN guard),
  4. kill-and-resume mid-run to demonstrate checkpoint/restart,
  5. round-trip through the modeling plane: CIMinus cost report of the
     same (now sparse) model on a multi-macro CIM architecture.

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps N] [--full]
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import hybrid, usecase_arch
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.sparsity.apply import (cim_cost_of_model, prune_params,
                                  sparsity_report)
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def lm_config(full: bool) -> ArchConfig:
    if full:
        return ArchConfig(                       # ~110M params
            name="lm-110m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=8192,
            gated_mlp=True, attention="global")
    return ArchConfig(                           # ~20M params (CPU-quick)
        name="lm-20m", family="dense", n_layers=6, d_model=384,
        n_heads=6, n_kv_heads=2, d_ff=1536, vocab_size=4096,
        gated_mlp=True, attention="global")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--full", action="store_true",
                    help="~110M params (slower on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = lm_config(args.full)
    spec = hybrid(2, 16, 0.75)   # 1:2 intra × row-block → overall 75 %
    pipe_cfg = PipelineConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch, seed=7)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 3, 1),
                             ckpt_dir=ckpt_dir, log_every=1, seed=0)

        # ---- prune, then sparse fine-tune ---------------------------------
        trainer = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5),
                          tcfg, TokenPipeline(pipe_cfg))
        trainer.params, masks = prune_params(trainer.params, spec)
        rep = sparsity_report(trainer.params, masks)
        print(f"model: {cfg.name}  params≈{cfg.param_count() / 1e6:.1f}M  "
              f"pruned density {rep['overall_density']:.3f}")

        trainer = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5),
                          tcfg, TokenPipeline(pipe_cfg), masks=masks)
        trainer.params, _ = prune_params(trainer.params, spec)

        half = args.steps // 2
        tcfg_half = TrainerConfig(**{**tcfg.__dict__, "steps": half})
        trainer.tcfg = tcfg_half
        log = trainer.train()
        print(f"[phase 1] {len(log)} steps, "
              f"loss {log[0]['loss']:.3f} → {log[-1]['loss']:.3f}")

        # ---- simulate failure: fresh Trainer resumes from checkpoint ------
        trainer2 = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5),
                           tcfg, TokenPipeline(pipe_cfg), masks=masks)
        assert trainer2.start_step > 0, "expected checkpoint auto-resume"
        print(f"[restart] resumed from step {trainer2.start_step} "
              f"(checkpoint/restart OK)")
        log2 = trainer2.train()
        losses = [m["loss"] for m in log2]
        print(f"[phase 2] {len(log2)} steps, final loss {losses[-1]:.3f}")

        # pruned weights stayed exactly zero through training
        w = np.asarray(trainer2.params["layers"]["w_up"])
        m = masks["layers"]["w_up"]
        leak = np.abs(w[m == 0]).max() if (m == 0).any() else 0.0
        print(f"[sparsity] max |w| on pruned positions: {leak:.2e}")

        # ---- modeling plane: CIMinus cost of this model on CIM ------------
        arch = usecase_arch(4)
        rep, c = cim_cost_of_model(cfg, arch, spec, seq_len=32)
        print(f"\n[CIMinus] {cfg.name} on {arch.name}: "
              f"latency {rep.latency_ms:.3f} ms, "
              f"energy {rep.total_energy_uj:.1f} uJ, "
              f"speedup vs dense {c['speedup']:.2f}x, "
              f"energy saving {c['energy_saving']:.2f}x")


if __name__ == "__main__":
    main()
