"""Serve a small model with batched requests (continuous-batching-lite).

Builds a reduced qwen3-family model, submits a mixed batch of requests
(different prompt lengths, different generation budgets), and drains the
slot pool while reporting throughput.  The decode step is jitted once at
fixed shapes — no recompilation as requests come and go.

Run:  PYTHONPATH=src python examples/serve_batched.py [--requests N]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config("qwen3-4b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=96)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        req = Request(
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, args.max_new + 1)))
        reqs.append(req)
        engine.submit(req)

    t0 = time.perf_counter()
    steps = 0
    while engine.queue or any(r is not None for r in engine.slot_req):
        engine.step()
        steps += 1
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests on {args.slots} slots "
          f"in {steps} engine steps / {dt:.2f}s")
    print(f"generated {total_tokens} tokens "
          f"({total_tokens / dt:.1f} tok/s on CPU)")
    for i, r in enumerate(reqs):
        assert r.done and len(r.output) == r.max_new_tokens
        print(f"  req{i}: prompt={len(r.prompt):2d} new={len(r.output):2d} "
              f"tokens={r.output[:6]}{'...' if len(r.output) > 6 else ''}")


if __name__ == "__main__":
    main()
